"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --smoke \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Local runs use the reduced smoke config on the host devices; the full
configs are sized for the production mesh (use ``repro.launch.dryrun`` to
validate those without hardware).  Data is the synthetic corpus matching
the arch's modality (see repro.data.synthetic).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.config import TrainConfig, get_config
from repro.data.synthetic import MarkovLM, MaskedFrames
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.models import seq2seq as S
from repro.optim import optimizer_init


def data_for(cfg, batch: int, seq: int, seed: int):
    if cfg.is_encoder_decoder:
        from repro.data.synthetic import PhraseMT

        task = PhraseMT(vocab=cfg.vocab_size, expand=2, seed=seed)
        return task.batches(batch=batch, src_len=max(seq // 2, 4), seed=seed)
    if cfg.modality == "audio":
        task = MaskedFrames(d_model=cfg.d_model,
                            codebook=min(cfg.vocab_size, 504), seed=seed)
        return task.batches(batch=batch, seq_len=seq, seed=seed)
    task = MarkovLM(vocab=min(cfg.vocab_size, 256), temperature=0.2,
                    seed=seed)
    gen = task.batches(batch=batch, seq_len=seq, seed=seed)
    if cfg.modality == "vision_text":
        def with_patches():
            for b in gen:
                b["patch_embeds"] = np.zeros((batch, 4, cfg.d_model),
                                             np.float32)
                yield b
        return with_patches()
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke).replace(dtype="float32")
    tc = TrainConfig(global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                     steps=args.steps, warmup_steps=max(args.steps // 10, 10),
                     head_loss="random" if cfg.bpd_enabled else "mean")
    init_fn = S.init if cfg.is_encoder_decoder else M.init
    params = init_fn(jax.random.PRNGKey(args.seed), cfg)
    opt = optimizer_init(params, tc)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start = latest_step(args.ckpt_dir)
        params, extra = restore(args.ckpt_dir, params)
        print(f"[train] restored step {start} from {args.ckpt_dir}")

    step_fn = jax.jit(steps_lib.make_train_step(cfg, tc))
    gen = data_for(cfg, args.batch, args.seq, args.seed + 1)
    key = jax.random.PRNGKey(args.seed + 2)
    t0 = time.time()
    for i in range(start, args.steps):
        key, sub = jax.random.split(key)
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, metrics = step_fn(params, opt, batch, sub)
        if (i + 1) % args.log_every == 0:
            rate = (i + 1 - start) * args.batch * args.seq / (time.time() - t0)
            print(f"[train] step {i + 1:5d}  "
                  f"loss {float(metrics['loss']):.4f}  "
                  f"acc {float(metrics.get('accuracy', 0)):.3f}  "
                  f"{rate:,.0f} tok/s", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, i + 1, params, extra={"arch": args.arch})
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, params, extra={"arch": args.arch})
        print(f"[train] final checkpoint -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
