"""Step factories + input specs for training, prefill and BPD serving.

These are what the launcher jits and what the multi-pod dry-run lowers:

  * ``train_step``   — forward + BPD multi-head loss + optimizer update
  * ``prefill_step`` — parallel forward building the KV caches + the first
                       block proposals (the paper's initial predict substep)
  * ``serve_step``   — ONE blockwise-parallel-decoding iteration: k proposal
                       tokens verified (and re-proposed) against the cache
                       (paper §4 combined model; decode_32k / long_500k)

``input_specs(cfg, shape)`` returns ShapeDtypeStructs for every model input —
weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import INPUT_SHAPES, DecodeConfig, ModelConfig, TrainConfig
from repro.core import decode as decode_lib
from repro.core.policy import resolve_policy
from repro.core.train import loss_fn_for
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.optim import optimizer_init, optimizer_update

F32, I32, BOOL = jnp.float32, jnp.int32, jnp.bool_


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def text_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Text positions = seq_len minus the modality/meta prefix."""
    n = cfg.num_meta_tokens
    if cfg.modality == "vision_text":
        n += cfg.num_patch_tokens
    return max(seq_len - n, 8)


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one entry of the assigned shape grid."""
    spec = INPUT_SHAPES[shape_name]
    b, s = spec["global_batch"], spec["seq_len"]
    sds = jax.ShapeDtypeStruct
    if cfg.modality == "audio":
        out = {"frame_embeds": sds((b, s, cfg.d_model), F32)}
        if spec["kind"] == "train":
            out["mask"] = sds((b, s), BOOL)
            out["targets"] = sds((b, s), I32)
        return out
    out = {"tokens": sds((b, text_len_for(cfg, s)), I32)}
    if cfg.modality == "vision_text":
        out["patch_embeds"] = sds((b, cfg.num_patch_tokens, cfg.d_model), F32)
    return out


# ---------------------------------------------------------------------------
# train_step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    mask: Any = None) -> Callable:
    loss_fn = loss_fn_for(cfg)

    def train_step(params, opt_state, batch, key):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, tc, batch, key), has_aux=True)(params)
        params, opt_state, opt_m = optimizer_update(grads, opt_state, params,
                                                    tc, mask=mask)
        metrics.update(opt_m)
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# prefill_step
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, dec: DecodeConfig,
                      *, kv_chunk: int = 0) -> Callable:
    if cfg.is_encoder_only:
        # encoder "prefill" = one full parallel encode producing code logits
        def encode_step(params, batch):
            h = model_lib.embed_inputs(params, cfg, batch)
            hidden, _, _ = model_lib.forward_hidden(params, cfg, h,
                                                    bidirectional=True,
                                                    kv_chunk=kv_chunk)
            return model_lib.project_vocab(params, cfg, hidden)

        return encode_step

    def prefill_step(params, batch):
        state, _ = decode_lib.bpd_prefill_causal_lm(
            params, cfg, dec, batch, max_new=dec.max_new_tokens,
            kv_chunk=kv_chunk)
        # return the serving state: caches + first proposals
        return state

    return prefill_step


# ---------------------------------------------------------------------------
# serve_step — one BPD iteration against an existing cache
# ---------------------------------------------------------------------------


def make_serve_step(cfg: ModelConfig, dec: DecodeConfig, *, seq_len: int,
                    max_new: int = 4096, kv_chunk: int = 0,
                    aux_params=None) -> Callable:
    """``aux_params`` ({bundle name: params}) is closed over for policies
    whose drafter runs an auxiliary model (see core.bundle); the default
    serve path is single-model."""
    prefix = cfg.num_meta_tokens + (
        cfg.num_patch_tokens if cfg.modality == "vision_text" else 0)
    backend = decode_lib.causal_lm_backend(cfg, kv_chunk=kv_chunk)
    pol = resolve_policy(dec)

    def serve_step(params, state: decode_lib.BPDState) -> decode_lib.BPDState:
        return decode_lib.bpd_iteration(
            params, cfg, dec, backend, state,
            prefix_offset=prefix, max_new=max_new, policy=pol,
            aux_params=aux_params)

    return serve_step


def serve_state_struct(cfg: ModelConfig, dec: DecodeConfig, *, batch: int,
                       seq_len: int, max_new: int = 4096):
    """ShapeDtypeStructs of the BPD serving state at context ``seq_len``.

    Includes the loop-carried policy state for ``dec``'s resolved policy
    (stateful schedules/drafters carry per-row arrays; the serve path is
    prompt-only, so drafters that need decode-entry inputs reject here).
    """
    block_k = dec.block_k or cfg.bpd_k
    prefix = cfg.num_meta_tokens + (
        cfg.num_patch_tokens if cfg.modality == "vision_text" else 0)
    pol = resolve_policy(dec)

    def mk():
        caches = model_lib.init_caches(cfg, batch, seq_len + max_new, block_k,
                                       backend=cache_lib.get_backend(dec))
        text_cap = seq_len - prefix + max_new + block_k
        return decode_lib.BPDState(
            tokens=jnp.zeros((batch, text_cap), I32),
            text_len=jnp.full((batch,), seq_len - prefix, I32),
            proposals=jnp.zeros((batch, block_k), I32),
            caches=caches,
            finished=jnp.zeros((batch,), bool),
            iters=jnp.zeros((), I32),
            generated=jnp.zeros((batch,), I32),
            policy_state=pol.init_state(cfg, dec, None, batch),
        )

    return jax.eval_shape(mk)


def materialize_serve_state(cfg: ModelConfig, dec: DecodeConfig, *, batch: int,
                            seq_len: int, max_new: int = 4096
                            ) -> decode_lib.BPDState:
    """Concrete (zeros) serving state — used by tests and local serving."""
    struct = serve_state_struct(cfg, dec, batch=batch, seq_len=seq_len,
                                max_new=max_new)
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), struct)


# ---------------------------------------------------------------------------
# Shape-grid adaptation (DESIGN.md §5): which (arch × shape) pairs run, and
# with which config variant.
# ---------------------------------------------------------------------------

LONG_WINDOW = 8192


def adapt_config(cfg: ModelConfig, shape_name: str) -> Optional[ModelConfig]:
    """Returns the config variant for this shape, or None if the pair is
    skipped (recorded in DESIGN.md)."""
    kind = INPUT_SHAPES[shape_name]["kind"]
    if cfg.is_encoder_only and kind == "decode":
        return None  # no autoregressive decode exists
    if shape_name == "long_500k":
        sub_quadratic = (cfg.block_type in ("rwkv6", "hymba")
                         or cfg.sliding_window)
        if not sub_quadratic:
            # dense/MoE/VLM: sliding-window variant (flagged, approximate)
            return cfg.replace(sliding_window=LONG_WINDOW)
    return cfg
