"""Serving launcher: restore a checkpoint and decode request batches with
blockwise parallel decoding.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --ckpt-dir /tmp/ckpt --batch 4 --max-new 32 \
        [--criterion topk --top-k 2] [--policy topk_tree] [--sched sjf] \
        [--policy draft_model --draft-arch granite-3-8b \
         --draft-ckpt /tmp/draft-ckpt] \
        [--engine --policies exact=2,topk_tree=2] \
        [--cache-backend paged --page-size 16]

``--cache-backend paged`` swaps the dense per-slot KV rows for the paged
cache (fixed-size pages, per-slot block tables, CoW prefix sharing); in
engine mode admissions allocate pages from a shared pool and identical
prompt prefixes are stored once.  Token-identical to dense.

``--policy`` selects the SESSION-DEFAULT decode policy (drafter ×
acceptor × block schedule, see README "Decode policies"); unset, the
legacy ``--criterion`` alias applies.  ``--sched`` picks the engine's
admission order (fcfs/sjf).

``--policies name=slots,name=slots`` (engine mode) partitions the slot
slab into per-policy slot groups and makes the decode policy a
PER-REQUEST field: each generated request carries a policy sampled from
the configured groups (``Request.policy``; unset requests fall back to
the ``--policy`` session default), and the engine schedules each group
with its own compile-once step.

``--policy draft_model`` serves with the speculative draft-model drafter:
a second (small) model — the ``--draft-arch`` smoke config, restored from
``--draft-ckpt`` when given — proposes each block autoregressively
through an auxiliary ``ModelBundle``, and the primary model verifies
losslessly.  Both modes (static batch and ``--engine``) thread the bundle
through the same ``DecodeSession``.

Runs the prefill + serve_step loop (the same entry points the multi-pod
dry-run lowers) on the host devices with the reduced config.

``--engine`` switches to the continuous-batching engine (repro.serving):
2×batch mixed-length requests are scheduled through ``--batch`` slots with
mid-flight admission, printing per-request stats and the aggregate
tokens/sec + latency summary.

``--mesh-data N --mesh-model M`` runs either mode through a mesh-backed
``DecodeSession``: params placed by ``sharding.policy.param_shardings``,
the decode state / slot batch sharded over the data axis and tensors over
the model axis.  On a CPU host prefix the command with
``XLA_FLAGS=--xla_force_host_platform_device_count=<N*M>``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore
from repro.config import DecodeConfig, get_config
from repro.data.synthetic import MarkovLM
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--block-k", type=int, default=0)
    ap.add_argument("--criterion", default="exact",
                    choices=["exact", "topk", "distance"],
                    help="legacy alias for --policy (the three acceptor "
                         "names are registered policies); prefer --policy")
    ap.add_argument("--policy", default="",
                    help="decode policy name (drafter × acceptor × "
                         "schedule; see repro.config.list_policies()); "
                         "empty = the --criterion legacy alias")
    ap.add_argument("--cache-backend", default="dense",
                    choices=["dense", "paged"],
                    help="KV cache layout: dense per-slot rows, or paged "
                         "(fixed-size pages + block tables + CoW prefix "
                         "sharing; engine mode allocates pages per request)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (multiple of 8; paged only)")
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--epsilon", type=float, default=2.0)
    ap.add_argument("--image-height", type=int, default=0,
                    help="2-D raster rows for the locality policy "
                         "(--policy locality / --policies locality=N): "
                         "the token stream is an image serialized in the "
                         "progressive-lattice order")
    ap.add_argument("--image-width", type=int, default=0,
                    help="2-D raster cols for the locality policy")
    ap.add_argument("--locality-stride", type=int, default=4,
                    help="coarse-lattice stride of the locality order "
                         "(power of two)")
    ap.add_argument("--draft-arch", default=None,
                    help="arch of the speculative draft model (smoke "
                         "config; --policy draft_model); defaults to "
                         "--arch — the draft vocab must match the primary")
    ap.add_argument("--draft-ckpt", default=None,
                    help="checkpoint dir for the draft model's params "
                         "(unset: randomly initialized — lossless but "
                         "slow, demo only)")
    ap.add_argument("--fused-verify", action="store_true",
                    help="route block acceptance through the one-pass "
                         "Pallas accept kernel (kernels/fused_verify; "
                         "token-identical opt-in — interpret-mode, i.e. "
                         "slow, off TPU)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", action="store_true",
                    help="serve through the continuous-batching engine "
                         "(slots + admission) instead of one static batch")
    ap.add_argument("--policies", default="",
                    help="engine-mode per-policy slot groups, e.g. "
                         "'exact=2,topk_tree=2' (must partition --batch); "
                         "requests then carry a per-request policy sampled "
                         "from the groups.  Empty: one group running the "
                         "--policy/--criterion session default")
    ap.add_argument("--sched", default="fcfs", choices=["fcfs", "sjf"],
                    help="engine admission policy (scheduler)")
    ap.add_argument("--http", action="store_true",
                    help="serve the continuous-batching engine over "
                         "HTTP/SSE (POST /v1/generate streams token "
                         "events; /healthz /readyz /metrics) instead of "
                         "replaying a synthetic workload")
    ap.add_argument("--host", default="127.0.0.1",
                    help="--http bind address")
    ap.add_argument("--port", type=int, default=8000,
                    help="--http bind port (0 = ephemeral, printed on "
                         "startup)")
    ap.add_argument("--max-queue", type=int, default=16,
                    help="--http admission queue bound: requests beyond "
                         "it are rejected with 429 + Retry-After")
    ap.add_argument("--http-demo", action="store_true",
                    help="with --http: boot the server, stream ONE "
                         "self-request end-to-end (printing the SSE "
                         "events), check /healthz + /readyz, then exit — "
                         "the CI smoke mode")
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="data-parallel shards (0 = no mesh, single device)")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="tensor-parallel shards over the model axis")
    ap.add_argument("--mesh-pod", type=int, default=1,
                    help="pod-parallel shards (production "
                         "('pod','data','model') mesh shape; prefill "
                         "workers shard over the pod axis, the slot slab "
                         "over pod×data)")
    ap.add_argument("--prefill-slots", type=int, default=0,
                    help="disaggregated prefill/decode: prompts prefilled "
                         "per prefill-worker forward, handed to decode "
                         "groups through the bounded KV-handoff queue "
                         "(0 = unified engine, admission prefills inline)")
    ap.add_argument("--handoff-cap", type=int, default=0,
                    help="bound on requests staged for / parked in the "
                         "KV-handoff queue (0 = auto)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True).replace(dtype="float32")
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    params = M.init(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        params, extra = restore(args.ckpt_dir, params)
        print(f"[serve] restored step {latest_step(args.ckpt_dir)} "
              f"({extra.get('arch')})")

    # --criterion is resolved here to a registered policy name so the
    # deprecated criterion-string fallback never fires downstream
    dec = DecodeConfig(max_new_tokens=args.max_new,
                       block_k=args.block_k or cfg.bpd_k,
                       policy=args.policy or args.criterion,
                       top_k=args.top_k, epsilon=args.epsilon,
                       cache_backend=args.cache_backend,
                       page_size=args.page_size,
                       fused_verify=args.fused_verify,
                       image_height=args.image_height,
                       image_width=args.image_width,
                       locality_stride=args.locality_stride)
    task = MarkovLM(vocab=min(cfg.vocab_size, 256), temperature=0.2,
                    seed=args.seed)
    prompts = jnp.asarray(task.sample(np.random.default_rng(args.seed + 1),
                                      args.batch, args.prompt_len))
    batch = {"tokens": prompts}
    if cfg.modality == "vision_text":
        batch["patch_embeds"] = jnp.zeros((args.batch, 4, cfg.d_model),
                                          jnp.float32)

    mesh = None
    if args.mesh_data > 0:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(args.mesh_data, args.mesh_model,
                              pod=args.mesh_pod, require=True)
        print(f"[serve] mesh {dict(mesh.shape)} over {mesh.size} devices")

    groups = parse_policy_groups(args.policies)
    if groups and not (args.engine or args.http):
        raise SystemExit("--policies configures per-request slot groups in "
                         "the continuous-batching engine: add --engine "
                         "(or --http)")
    bundles = draft_bundle(cfg, args, groups)

    if args.http:
        serve_http(params, cfg, dec, args, mesh=mesh, bundles=bundles,
                   groups=groups)
        return

    if args.engine:
        serve_engine(params, cfg, dec, args, task, mesh=mesh,
                     bundles=bundles, groups=groups)
        return

    # static batch through the same session layer the engine uses —
    # jitted once (with explicit shardings when a mesh is given)
    from repro.serving import DecodeSession
    sess = DecodeSession(params, cfg, dec, mesh=mesh, jit=True,
                         bundles=bundles)
    sess.decode(batch)  # compile
    t0 = time.time()
    toks, stats = sess.decode(batch)
    jax.block_until_ready(toks)
    dt = time.time() - t0

    print(f"[serve] {args.batch} requests, {args.max_new} tokens each, "
          f"policy={sess.policy.name}")
    print(f"[serve] mean accepted block size k̂ = "
          f"{float(stats['mean_accepted']):.2f}  "
          f"invocations = {int(stats['invocations'])} "
          f"(greedy would need {args.max_new + 1})  wall = {dt * 1e3:.0f}ms")
    for r in range(args.batch):
        n = int(stats["text_len"][r])
        out = [int(x) for x in np.asarray(toks[r, args.prompt_len:n])]
        print(f"    row {r}: {out}")


def parse_policy_groups(spec: str):
    """'exact=2,topk_tree=2' -> {"exact": 2, "topk_tree": 2} (None when
    empty).  Every error names its fix here, at the flag, instead of
    surfacing later as an EngineConfig/registry failure mid-compile.
    Slot counts must partition --batch; the engine validates that part."""
    if not spec:
        return None
    from repro.config import list_policies

    known = list_policies()
    groups = {}
    for part in spec.split(","):
        name, sep, n = part.strip().partition("=")
        if not sep or not name or not n.lstrip("+-").isdigit():
            raise SystemExit(f"--policies entry {part!r}: expected "
                             f"name=slots, e.g. exact=2")
        if name not in known:
            raise SystemExit(f"--policies names unknown policy {name!r}: "
                             f"registered policies are "
                             f"{', '.join(sorted(known))}")
        if name in groups:
            raise SystemExit(f"--policies names {name!r} twice: one slot "
                             f"group per policy — merge the counts into a "
                             f"single {name}=n entry")
        slots = int(n)
        if slots <= 0:
            raise SystemExit(f"--policies entry {part.strip()!r}: slot "
                             f"count must be a positive integer (every "
                             f"group needs at least one slot)")
        groups[name] = slots
    return groups


def draft_bundle(cfg, args, groups=None):
    """Build the auxiliary draft ``ModelBundle`` when any served policy is
    draft_model (None otherwise): the --draft-arch smoke config (default:
    the primary arch), restored from --draft-ckpt when given."""
    if args.policy != "draft_model" and "draft_model" not in (groups or {}):
        return None
    from repro.core.bundle import ModelBundle

    dcfg = get_config(args.draft_arch or args.arch,
                      smoke=True).replace(dtype="float32", bpd_enabled=False)
    dparams = M.init(jax.random.PRNGKey(args.seed + 7), dcfg)
    if args.draft_ckpt and latest_step(args.draft_ckpt) is not None:
        dparams, extra = restore(args.draft_ckpt, dparams)
        print(f"[serve] draft model: restored step "
              f"{latest_step(args.draft_ckpt)} ({extra.get('arch')})")
    else:
        print(f"[serve] draft model: {dcfg.name} (randomly initialized — "
              f"lossless, but expect k̂ ≈ 1; pass --draft-ckpt for a real "
              f"draft)")
    return {"draft": ModelBundle(dparams, dcfg)}


def serve_engine(params, cfg, dec, args, task, *, mesh=None, bundles=None,
                 groups=None):
    """Mixed-length request traffic through the continuous-batching engine
    — with ``groups``, mixed-POLICY traffic over per-policy slot groups."""
    from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                               Request, Scheduler, aggregate_stats)

    ecfg = EngineConfig(num_slots=args.batch,
                        max_prompt_len=args.prompt_len,
                        max_new_cap=args.max_new)
    engine = ContinuousBatchingEngine(params, cfg, dec, ecfg, mesh=mesh,
                                      bundles=bundles, policies=groups)
    sched = Scheduler(engine, policy=args.sched)

    rng = np.random.default_rng(args.seed + 2)
    names = engine.policy_names()
    n = 2 * args.batch
    for rid in range(n):
        plen = int(rng.integers(max(args.prompt_len // 2, 1),
                                args.prompt_len + 1))
        sched.submit(Request(
            rid=rid, prompt=task.sample(rng, 1, plen)[0],
            max_new=int(rng.integers(max(args.max_new // 4, 1),
                                     args.max_new + 1)),
            # the per-request policy field: sampled over the slot groups
            # (None when the engine runs one default group)
            policy=str(rng.choice(names)) if groups else None))

    t0 = time.time()
    finished = sched.run()
    wall = time.time() - t0
    stats = aggregate_stats(finished, wall)

    print(f"[serve] engine: {n} requests over {args.batch} slots "
          f"(sched={args.sched}, "
          f"{'groups=' + str(groups) if groups else 'policy=' + engine.policy.name})")
    print(f"[serve] {stats['total_tokens']} tokens in "
          f"{stats['total_invocations']} invocations, "
          f"{stats['tokens_per_sec']:.0f} tok/s, "
          f"p50 {stats['latency_p50_s'] * 1e3:.0f}ms / "
          f"p95 {stats['latency_p95_s'] * 1e3:.0f}ms, "
          f"compile {engine.compile_counts()}")
    for f in sorted(finished, key=lambda f: f.rid):
        print(f"    req {f.rid} [{f.policy}]: k̂={f.mean_accepted:.2f} "
              f"gen={f.generated} inv={f.invocations} "
              f"out={[int(x) for x in f.tokens]}")


def serve_http(params, cfg, dec, args, *, mesh=None, bundles=None,
               groups=None):
    """Serve the engine over HTTP/SSE (see serving.server for the routes);
    ``--http-demo`` instead streams one self-request and exits (CI smoke)."""
    import asyncio

    from repro.serving import (ContinuousBatchingEngine, EngineConfig,
                               Frontend, HTTPServer, Scheduler)

    ecfg = EngineConfig(num_slots=args.batch,
                        max_prompt_len=args.prompt_len,
                        max_new_cap=args.max_new,
                        prefill_slots=args.prefill_slots,
                        handoff_cap=args.handoff_cap)
    engine = ContinuousBatchingEngine(params, cfg, dec, ecfg, mesh=mesh,
                                      bundles=bundles, policies=groups)
    sched = Scheduler(engine, policy=args.sched)
    srv = HTTPServer(Frontend(sched, max_queue=args.max_queue),
                     host=args.host, port=args.port)

    async def run():
        import signal

        await srv.start()
        # SIGTERM → graceful drain: stop admission, finish what's in
        # flight (SSE tails flush), close the listener, exit 0 — the same
        # path POST /drain takes
        loop = asyncio.get_running_loop()
        try:
            loop.add_signal_handler(signal.SIGTERM, srv.begin_drain)
            loop.add_signal_handler(signal.SIGINT, srv.begin_drain)
        except NotImplementedError:    # non-Unix event loops
            pass
        mode = (f"disaggregated prefill_slots={args.prefill_slots}"
                if args.prefill_slots else "unified")
        print(f"[serve] http on {srv.host}:{srv.port} — POST /v1/generate "
              f"/drain, GET /healthz /readyz /metrics "
              f"(slots={args.batch}, sched={args.sched}, "
              f"max_queue={args.max_queue}, {mode})", flush=True)
        if args.http_demo:
            await _http_demo(srv)
            await srv.stop()
        else:
            await srv.serve_forever()
            print("[serve] drained — exiting", flush=True)

    asyncio.run(run())


async def _http_demo(srv):
    """One end-to-end streamed request against the live server, over a
    real socket: asserts SSE token + done events and green health checks,
    exiting non-zero on any miss — the CI server-smoke contract."""
    import asyncio
    import json

    async def get(path):
        r, w = await asyncio.open_connection(srv.host, srv.port)
        w.write(f"GET {path} HTTP/1.1\r\nHost: {srv.host}\r\n\r\n".encode())
        await w.drain()
        data = await r.read()
        w.close()
        return data.decode()

    for path in ("/healthz", "/readyz"):
        status = (await get(path)).splitlines()[0]
        print(f"[serve] {path} -> {status}")
        if "200" not in status:
            raise SystemExit(f"--http-demo: {path} returned {status!r}")

    body = json.dumps({"prompt": [5, 6, 7, 8], "max_new": 12,
                       "stream": True}).encode()
    r, w = await asyncio.open_connection(srv.host, srv.port)
    w.write(b"POST /v1/generate HTTP/1.1\r\n"
            + f"Host: {srv.host}\r\n".encode()
            + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await w.drain()
    raw = (await r.read()).decode()
    w.close()
    print("[serve] SSE stream:")
    print("    " + "\n    ".join(ln for ln in raw.splitlines() if ln))
    events, cur = [], None
    for ln in raw.splitlines():
        if ln.startswith("event: "):
            cur = ln[7:]
        elif ln.startswith("data: ") and cur is not None:
            events.append((cur, json.loads(ln[6:])))
    tokens = [t for kind, d in events if kind == "token"
              for t in d["tokens"]]
    dones = [d for kind, d in events if kind == "done"]
    if not tokens or not dones:
        raise SystemExit("--http-demo: stream missing token/done SSE events")
    done = dones[0]
    # the done payload repeats the full stream — they must agree exactly
    if tokens != done["tokens"]:
        raise SystemExit("--http-demo: streamed tokens disagree with the "
                         "done payload")
    print(f"[serve] demo ok: {done['generated']} tokens streamed, "
          f"k̂={done['mean_accepted']:.2f}")


if __name__ == "__main__":
    main()
