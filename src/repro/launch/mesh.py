"""Production mesh construction (TPU v5e pods).

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
XLA_FLAGS before anything initializes the backend.
"""
from __future__ import annotations

import jax

from repro.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2×16×16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(mc: MeshConfig):
    return jax.make_mesh(mc.shape, mc.axes)


def make_host_mesh(data: int = 1, model: int = 1, *, pod: int = 1,
                   require: bool = False):
    """Small host mesh over whatever devices exist (tests / local runs):
    ("data", "model"), or the production ("pod", "data", "model") shape
    when ``pod > 1`` — the host-scale twin of ``make_production_mesh``'s
    multi-pod layout (prefill workers shard over the pod axis, the slot
    slab over pod×data; see sharding.policy).  Axes shrink to fit the
    available device count unless ``require=True`` — then an
    under-provisioned host raises instead of silently degrading a sharded
    run to fewer shards (use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to fake N CPU
    devices)."""
    n = len(jax.devices())
    p = min(pod, n)
    d = min(data, max(n // p, 1))
    m = min(model, max(n // (p * d), 1))
    if require and (p, d, m) != (pod, data, model):
        need = pod * data * model
        raise RuntimeError(
            f"host mesh {pod}x{data}x{model} needs {need} devices, have "
            f"{n} — set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} before jax initializes")
    if pod > 1:
        return jax.make_mesh((p, d, m), ("pod", "data", "model"))
    return jax.make_mesh((d, m), ("data", "model"))


# Hardware constants for roofline analysis (TPU v5e, per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
