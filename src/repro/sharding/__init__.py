from repro.sharding.policy import (
    batch_axes,
    batch_specs,
    bundle_param_shardings,
    cache_specs,
    data_axis_size,
    data_spec,
    named,
    param_shardings,
    param_specs,
    slot_specs,
    state_specs,
)

__all__ = [
    "batch_axes",
    "batch_specs",
    "bundle_param_shardings",
    "cache_specs",
    "data_axis_size",
    "data_spec",
    "named",
    "param_shardings",
    "param_specs",
    "slot_specs",
    "state_specs",
]
