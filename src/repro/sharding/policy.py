"""Sharding policy: parameter/cache/batch PartitionSpecs for the production
mesh.

Scheme (Megatron-style tensor parallelism under GSPMD):
  * ``model`` axis: attention heads / kv heads, FFN width, experts, vocab,
    SSM inner channels, BPD head hidden width.
  * ``data`` (+ ``pod``) axes: the batch dimension of activations, caches
    and inputs.  Gradient all-reduce over data/pod is inserted by GSPMD.
  * Norm scales, routers, token-shift anchors, small LoRA factors: replicated.

Everything is rule-based on parameter path names so new modules inherit
sensible defaults; rules are ordered, first match wins.
"""
from __future__ import annotations

import math
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.utils.tree import tree_map_with_name

Pytree = Any

# (regex on 'a/b/c' param path, PartitionSpec) — first match wins.
PARAM_RULES: Tuple[Tuple[str, P], ...] = (
    # --- embeddings / unembedding -------------------------------------------
    (r"(^|/)embed/table$", P("model", None)),
    (r"(^|/)src_embed/table$", P("model", None)),
    (r"(^|/)lm_head/w$", P(None, "model")),
    (r"(^|/)pos_embed$", P()),
    (r"(^|/)enc_pos$", P()),
    (r"(^|/)meta_tokens$", P()),
    (r"(^|/)mask_embed$", P()),
    # --- attention ------------------------------------------------------------
    (r"(attn|cross)/wq$", P(None, "model", None)),
    (r"(attn|cross)/wk$", P(None, "model", None)),
    (r"(attn|cross)/wv$", P(None, "model", None)),
    (r"(attn|cross)/wo$", P("model", None, None)),
    # --- MoE -------------------------------------------------------------------
    (r"moe/router/", P()),
    (r"moe/w1$", P("model", None, None)),
    (r"moe/w2$", P("model", None, None)),
    (r"moe/w3$", P("model", None, None)),
    (r"moe/shared/w1/w$", P(None, "model")),
    (r"moe/shared/w3/w$", P(None, "model")),
    (r"moe/shared/w2/w$", P("model", None)),
    (r"moe/shared/gate/", P()),
    # --- dense MLP --------------------------------------------------------------
    (r"mlp/w1/w$", P(None, "model")),
    (r"mlp/w3/w$", P(None, "model")),
    (r"mlp/w2/w$", P("model", None)),
    # --- RWKV6 -------------------------------------------------------------------
    (r"tm/w[rkvg]$", P(None, "model")),
    (r"tm/wo$", P("model", None)),
    (r"tm/u$", P("model", None)),
    (r"tm/(mu|mu_x|mix_A|mix_B|w0|decay_A|decay_B)$", P()),
    (r"tm/ln_x/", P()),
    (r"cm/wk$", P(None, "model")),
    (r"cm/wv$", P("model", None)),
    (r"cm/wr$", P(None, "model")),
    (r"cm/mu_[kr]$", P()),
    # --- Mamba (hymba SSM heads) ---------------------------------------------------
    (r"mamba/in_proj/w$", P(None, "model")),
    (r"mamba/conv_w$", P(None, "model")),
    (r"mamba/conv_b$", P("model")),
    (r"mamba/x_proj/w$", P("model", None)),
    (r"mamba/dt_proj/w$", P(None, "model")),
    (r"mamba/dt_proj/b$", P("model")),
    (r"mamba/A_log$", P("model", None)),
    (r"mamba/D$", P("model")),
    (r"mamba/out_proj/w$", P("model", None)),
    # --- BPD heads (the paper's multi-output layer) ---------------------------------
    (r"bpd_heads/w1$", P(None, None, "model")),
    (r"bpd_heads/b1$", P(None, "model")),
    (r"bpd_heads/w2$", P(None, "model", None)),
    (r"bpd_heads/b2$", P()),
)

DEFAULT_SPEC = P()  # norms, biases, scalars


def _spec_for(name: str) -> P:
    for pattern, spec in PARAM_RULES:
        if re.search(pattern, name):
            return spec
    return DEFAULT_SPEC


def _divisible(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the array does not divide evenly.  pjit argument
    shardings require exact divisibility (GSPMD only pads intermediates), so
    e.g. kv_heads=5 or vocab not a multiple of the model axis falls back to
    replicated on that dim.  Vocab dims avoid this via padded_vocab_size."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        size = math.prod(mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,)))
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def param_specs(params: Pytree, mesh: Mesh) -> Pytree:
    """PartitionSpec pytree mirroring ``params``."""
    return tree_map_with_name(
        lambda name, x: _divisible(_spec_for(name), x.shape, mesh), params)


def param_shardings(params: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  param_specs(params, mesh))


def bundle_param_shardings(bundles: dict, mesh: Mesh) -> dict:
    """Per-bundle parameter shardings: {name: NamedSharding pytree} for a
    ``{name: core.bundle.ModelBundle}`` mapping — what a ``DecodeSession``
    device_puts each auxiliary bundle with.  Every bundle's params go
    through the same path-rule table, so a draft model's attention/MLP
    weights shard on the ``model`` axis exactly like the primary model's
    (dims that don't divide fall back to replicated per leaf)."""
    return {name: param_shardings(b.params, mesh)
            for name, b in bundles.items()}


# ---------------------------------------------------------------------------
# Batch / activation / cache specs
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh, batch_size: int):
    """Mesh axes to shard the batch dim over (None = replicate)."""
    names = mesh.axis_names
    cand = tuple(a for a in ("pod", "data") if a in names)
    if cand:
        n = math.prod(mesh.shape[a] for a in cand)
        if n and batch_size % n == 0:
            return cand
    if "data" in names and batch_size % mesh.shape["data"] == 0:
        return ("data",)
    return None


def data_spec(mesh: Mesh, batch_size: int, ndim: int, *,
              extra: Optional[dict] = None) -> P:
    """P(batch_axes, None, ...) for a batch-leading array."""
    ax = batch_axes(mesh, batch_size)
    dims = [ax] + [None] * (ndim - 1)
    if extra:
        for i, a in extra.items():
            dims[i] = a
    return P(*dims)


def batch_specs(mesh: Mesh, batch: Pytree) -> Pytree:
    """Shard every batch leaf on its leading dim."""
    return jax.tree_util.tree_map(
        lambda x: data_spec(mesh, x.shape[0], x.ndim), batch)


_AUTO = "auto"


def prefill_axes(mesh: Mesh, batch_size: int):
    """Mesh axes a PREFILL-worker batch shards over: the ``pod`` axis alone.

    Disaggregated serving places prefill workers and decode groups on
    distinct data-axis slices — prefill packets live on the pod axis, the
    decode slot slab on pod×data, and the attach-time resharding between
    the two is the measured KV-handoff transfer.  On pod-less meshes (and
    whenever the width doesn't divide the pod axis) packets replicate,
    matching the historical batch-1 admission ("single-row prefill is
    replicated work")."""
    names = mesh.axis_names
    if ("pod" in names and mesh.shape["pod"] > 1
            and batch_size % mesh.shape["pod"] == 0):
        return ("pod",)
    return None


def cache_specs(cfg: ModelConfig, caches: Pytree, mesh: Mesh,
                batch_size: int, *, ax=_AUTO) -> Pytree:
    """Decode caches: batch over data axes; kv-heads over model where the
    head count divides the axis, otherwise the buffer LENGTH dim shards over
    model (flash-decoding-style sequence sharding: the softmax/PV reductions
    over the sharded length become GSPMD all-reduces, and attn_buf_len pads
    the buffer to a multiple of 256 so it always divides).

    ``ax`` overrides the batch-dim axes (default: ``batch_axes``) — the
    prefill-worker packet passes ``prefill_axes`` so its rows live on the
    pod slice instead of the full data split."""
    if ax is _AUTO:
        ax = batch_axes(mesh, batch_size)
    msz = mesh.shape.get("model", 1)
    kv_divides = cfg.num_kv_heads and cfg.num_kv_heads % msz == 0

    def spec(name: str, x) -> P:
        if "/attn/" in name and name.endswith(("/kp", "/vp")):
            # paged page pool: pages are shared across rows (CoW prefix
            # reuse), so the pool NEVER shards over the data axes — it
            # replicates there, trading the dense layout's data-parallel
            # split for the much larger paging win.  kv-heads shard over
            # model when they divide; the page-gather read path cannot
            # length-shard, so the fallback is replication.
            if kv_divides:
                return _divisible(P(None, None, "model", None), x.shape, mesh)
            return P()
        if name.endswith("/tbl"):
            return P(ax, None)
        if name.endswith("/pos"):
            if not kv_divides and x.ndim == 2 and x.shape[1] % msz == 0:
                return P(ax, "model")
            return P(ax, None)
        if "/attn/" in name and name[-2:] in ("/k", "/v"):
            if kv_divides:
                return _divisible(P(ax, None, "model", None), x.shape, mesh)
            return _divisible(P(ax, "model", None, None), x.shape, mesh)
        if "/tm/" in name:  # rwkv: state (B,H,D,D), shifts (B,d)
            if "state" in name:
                return _divisible(P(ax, "model", None, None), x.shape, mesh)
            return P(ax, None)
        if "/mamba/" in name:
            if name.endswith("/h") or "h_steps" in name:
                return _divisible(P(ax, "model", None), x.shape, mesh)
            return _divisible(P(ax, None, "model"), x.shape, mesh)
        # default: batch-leading
        return P(*([ax] + [None] * (x.ndim - 1)))

    return tree_map_with_name(spec, caches)


# ---------------------------------------------------------------------------
# Loop-carried decode state specs (BPDState / GreedyState / SlotBatch)
# ---------------------------------------------------------------------------


def state_specs(cfg: ModelConfig, state: Any, mesh: Mesh, *,
                batch_size: Optional[int] = None,
                draft_cfg: Optional[ModelConfig] = None,
                policy: Any = None, ax=_AUTO) -> Any:
    """PartitionSpec pytree for a batch-leading decode loop state.

    ``state`` is any NamedTuple whose arrays lead with the batch dimension
    (``BPDState``, ``GreedyState``, the serving ``SlotBatch``) and whose
    ``caches`` field is a per-layer cache pytree: the caches get the full
    ``cache_specs`` treatment (kv-heads or buffer length over ``model``),
    every other (B, ...) array shards its leading dim over the data axes,
    and scalars (loop counters) are replicated.  Works on concrete arrays
    and on ``ShapeDtypeStruct`` trees alike.

    The ``policy_state`` field (a ``core.policy.PolicyState`` pytree of
    loop-carried drafter/schedule state) is covered by the same rule: the
    policy contract requires batch-leading ``(B, ...)`` leaves, so e.g. an
    ``InputCopyDrafter``'s source batch or an ``AdaptiveSchedule``'s
    per-row cap shard over the data axes with the rest of the decode
    state.

    ``draft_cfg`` (the bound drafter's own model config — a
    ``DecodeSession`` reads it off ``policy.drafter.cfg``) upgrades
    model-backed drafter state: a drafter state dict carrying a
    ``"caches"`` cache pytree (the ``draft_model`` policy's loop-carried
    draft KV cache) gets the full ``cache_specs`` treatment under the
    DRAFT model's config instead of the generic batch-leading rule.

    ``policy`` (a bound ``core.policy.DecodePolicy``) is the per-group
    form of the same information: when given and ``draft_cfg`` is not,
    the draft config is read off ``policy.drafter.cfg`` — so callers that
    build specs for several policy slot groups (the serving engine) pass
    each group's own policy instead of one session-global draft config.
    """
    if draft_cfg is None and policy is not None:
        draft_cfg = getattr(policy.drafter, "cfg", None)
    b = batch_size if batch_size is not None else state.tokens.shape[0]
    if ax is _AUTO:
        ax = batch_axes(mesh, b)

    def leaf(x) -> P:
        if x.ndim >= 1 and x.shape[0] == b:
            return P(*([ax] + [None] * (x.ndim - 1)))
        return P()

    def policy_specs(ps):
        dstate = ps.drafter
        if (draft_cfg is not None and isinstance(dstate, dict)
                and "caches" in dstate):
            drafter = {k: cache_specs(draft_cfg, v, mesh, b, ax=ax)
                       if k == "caches"
                       else jax.tree_util.tree_map(leaf, v)
                       for k, v in dstate.items()}
        else:
            drafter = jax.tree_util.tree_map(leaf, dstate)
        return type(ps)(drafter=drafter,
                        schedule=jax.tree_util.tree_map(leaf, ps.schedule))

    fields = {}
    for name, val in state._asdict().items():
        if name == "caches" and val is not None:
            fields[name] = cache_specs(cfg, val, mesh, b, ax=ax)
        elif name == "policy_state" and hasattr(val, "drafter"):
            fields[name] = policy_specs(val)
        else:
            fields[name] = jax.tree_util.tree_map(leaf, val)
    return type(state)(**fields)


def slot_specs(cfg: ModelConfig, slots: Any, mesh: Mesh, *,
               draft_cfg: Optional[ModelConfig] = None,
               policy: Any = None) -> Any:
    """Specs for the serving engine's ``SlotBatch`` (slot dim == batch dim).

    Identical derivation to ``state_specs`` — the slot batch IS the decode
    batch; admission/eviction scatters stay local to the owning data shard.
    Called once per policy slot group: each group's ``SlotBatch`` is its
    own view of the engine's slot slab, so ``policy=`` (the GROUP's bound
    policy) lets a model-backed drafter's cache spec under its own draft
    config while other groups in the same engine spec generically.
    """
    return state_specs(cfg, slots, mesh, batch_size=slots.tokens.shape[0],
                       draft_cfg=draft_cfg, policy=policy)


def packet_specs(cfg: ModelConfig, packet: Any, mesh: Mesh, *,
                 draft_cfg: Optional[ModelConfig] = None,
                 policy: Any = None) -> Any:
    """Specs for a prefill worker's handoff packet (``PrefillPacket``).

    Same derivation as ``state_specs`` but the batch (= prefill width) dim
    shards over ``prefill_axes`` — the pod axis alone — instead of the
    full pod×data product: prefill workers own their data-axis slice, and
    attaching a packet row into the ("pod", "data")-sharded slot slab is
    the prefill→decode KV handoff the dry-run measures.
    """
    b = packet.tokens.shape[0]
    return state_specs(cfg, packet, mesh, batch_size=b, draft_cfg=draft_cfg,
                       policy=policy, ax=prefill_axes(mesh, b))


def data_axis_size(mesh: Mesh) -> int:
    """Number of shards the batch/slot dim splits into on this mesh."""
    return math.prod(mesh.shape[a] for a in ("pod", "data")
                     if a in mesh.axis_names)


def _active_mesh():
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def maybe_shard(x, spec: P):
    """with_sharding_constraint that no-ops when no mesh is active, so model
    code can carry GSPMD hints without making tests mesh-dependent."""
    if _active_mesh() is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def maybe_shard_expert(x):
    """Constraint for (B, Ep, C, d) expert-parallel MoE buffers: batch over
    the data axes, experts over model.  Axes are derived from the ACTIVE
    mesh (so the same model code lowers on single-pod and multi-pod meshes)
    and dropped when the dim doesn't divide (e.g. batch=1 long-context)."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    b_ax = batch_axes(mesh, x.shape[0])
    spec = _divisible(P(b_ax, "model", None, None), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def named(mesh: Mesh, tree_of_specs: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))
